"""CI gate over the serving benchmark artifacts (stdlib only).

    python tools/check_bench.py NEW.json [BASELINE.json] \
        [CLUSTER_NEW.json] [FLEET_NEW.json] [KERNELS_NEW.json]

Asserts, against the fresh ``bench_serving.py --json`` output:

1. ``channel_trace.adaptive_wins`` — the in-flight adaptive controller must
   use <= decode wire bytes/token at an equal-or-better deadline-miss rate
   than admission-frozen modes (the paper's dynamic-adaptation claim);
2. ``engine_comparison.decode_speedup`` — the device-resident decode loop
   must beat the legacy host loop by at least ``MIN_LOOP_SPEEDUP`` (a
   machine-independent in-run ratio: both loops run on the same box in the
   same process);
3. ``long_prompt`` — the paged pool must finish EVERY prompt longer than
   the dense per-slot cache with zero capacity rejections and zero
   truncation (while the in-run dense control rejects them all), and its
   decode tokens/s must clear ``BENCH_TOLERANCE`` x the committed
   baseline's figure;
4. ``slot_scaling`` (required when ``REQUIRE_SLOT_SCALING=1`` — the
   multi-device CI job sets it; absent otherwise since single-device
   runs cannot produce sharded rows) — every row must finish all its
   requests, and each dp>1 row's decode tokens/s must clear
   ``SHARD_FLOOR`` x the dp=1 row from the same run (mesh sharding on a
   forced-device CPU host must not crater throughput; real speedups
   need real accelerators);
5. decode tokens/s must not regress below ``BENCH_TOLERANCE`` x the
   committed baseline (matched per offered-load level, plus the
   device-loop figure). The tolerance is deliberately loose — CI runners
   vary widely in absolute speed; the in-run ratio above is the sharp
   check — and the committed baseline should be refreshed by any PR that
   intentionally moves serving performance.

And, when a fresh ``bench_cluster.py --json`` artifact is given:

6. ``handover_ab.migration_wins`` — live migration must beat
   stay-and-degrade on deadline-miss rate (the edge-cluster subsystem's
   headline claim — an in-run A/B on identical mobility scripts);
7. cluster scaling sanity: every multi-replica aggregate decode tokens/s
   must stay above ``SCALE_FLOOR`` x the single-replica figure from the
   same run (adding replicas must never crater throughput), plus the
   usual ``BENCH_TOLERANCE`` regression check against the committed
   baseline's ``cluster`` section.

And, when a fresh ``bench_fleet.py --json`` artifact is given (MANDATORY
whenever the committed baseline carries a ``fleet`` section — a missing
artifact must fail, not silently un-gate city-scale serving):

8. ``autoscale_ab.autoscaler_wins`` — at equal aggregate slots the
   autoscaled cluster must beat the statically-provisioned one on
   session-SLO miss rate (the elasticity subsystem's headline claim —
   an in-run A/B on identical flash-crowd arrival scripts);
9. fleet scaling sanity: every row of the UE scaling curve must
   terminate its whole fleet (finished + rejected == ues), and decode
   tokens/s at every level must stay above ``FLEET_FLOOR`` x the
   smallest fleet's figure from the same run (monotone-ish: more
   offered load must never crater the served rate), plus the usual
   ``BENCH_TOLERANCE`` regression check against the committed
   baseline's ``fleet.scaling`` rows.

And, when a fresh ``bench_kernels.py --json`` artifact is given (MANDATORY
whenever the committed baseline carries a ``kernels`` section — the fused
decode tail must stay gated once it has ever been benchmarked):

10. ``mega_parity_ok`` — the fused boundary+norm+head+argmax tick must
    produce exactly the tokens the unfused three-dispatch chain produces
    (an in-run bit-identity check);
11. ``mega_speedup`` — the fused tick must beat the unfused chain by at
    least ``MEGA_FLOOR`` (an in-run same-box ratio, like
    ``MIN_LOOP_SPEEDUP``), plus the usual ``BENCH_TOLERANCE`` regression
    check of ``mega_fused_tick_us`` against the committed baseline.

And, always, over the serving artifact's telemetry sections
(see docs/observability.md):

12. every offered-load level must carry a ``latency`` section with
    TTFT and inter-token p50/p90/p99 percentiles (``run_level`` always
    instruments — a missing section means telemetry silently fell off
    the measured path);
13. ``telemetry_overhead.ratio`` — the fully-instrumented engine must
    keep at least ``TELEMETRY_FLOOR`` x the uninstrumented engine's
    decode tokens/s (an in-run interleaved A/B on the same box —
    observability must stay off the hot path).

Environment overrides: ``MIN_LOOP_SPEEDUP`` (default 1.15),
``BENCH_TOLERANCE`` (default 0.3), ``SCALE_FLOOR`` (default 0.5),
``FLEET_FLOOR`` (default 0.5), ``SHARD_FLOOR`` (default 0.1),
``MEGA_FLOOR`` (default 1.0), ``TELEMETRY_FLOOR`` (default 0.95),
``REQUIRE_SLOT_SCALING`` (default unset),
``FLEET_OPTIONAL`` / ``KERNELS_OPTIONAL`` (default unset — set to 1 in
jobs that legitimately skip the fleet / kernel bench).
"""
from __future__ import annotations

import json
import os
import sys


def check_cluster(cl: dict, baseline: dict | None) -> list:
    """Gates over the ``bench_cluster.py`` artifact (``baseline`` is the
    committed BENCH_serving.json, whose ``cluster`` section pins the
    scaling reference)."""
    failures = []
    scale_floor = float(os.environ.get("SCALE_FLOOR", "0.5"))
    tolerance = float(os.environ.get("BENCH_TOLERANCE", "0.3"))

    ab = cl.get("handover_ab")
    if ab is None:
        failures.append("handover_ab missing from the cluster artifact")
    elif not ab.get("migration_wins"):
        failures.append(
            "live migration must beat stay-and-degrade on deadline-miss "
            f"rate: migrate {ab.get('migrate')} vs stay {ab.get('stay')}")

    scaling = cl.get("scaling") or []
    single = next((s for s in scaling if s["replicas"] == 1), None)
    if not scaling:
        failures.append("scaling sweep missing from the cluster artifact")
    elif single is None:
        # a sweep without the single-replica anchor cannot evaluate the
        # floor — that must fail loudly, not silently un-gate scaling
        failures.append("scaling sweep has no single-replica entry to "
                        "anchor the SCALE_FLOOR check")
    else:
        floor = scale_floor * single["decode_tok_per_s"]
        for s in scaling:
            if s["replicas"] > 1 and s["decode_tok_per_s"] < floor:
                failures.append(
                    f"{s['replicas']}-replica decode "
                    f"{s['decode_tok_per_s']} tok/s fell below "
                    f"{floor:.1f} ({scale_floor} x the single-replica "
                    f"{single['decode_tok_per_s']} from the same run)")
    base_cl = (baseline or {}).get("cluster")
    if base_cl is not None:
        base_scaling = {s["replicas"]: s
                        for s in base_cl.get("scaling", [])}
        for s in scaling:
            base = base_scaling.get(s["replicas"])
            if base is None:
                continue
            floor = tolerance * base["decode_tok_per_s"]
            if s["decode_tok_per_s"] < floor:
                failures.append(
                    f"{s['replicas']}-replica decode "
                    f"{s['decode_tok_per_s']} tok/s regressed below "
                    f"{floor:.1f} ({tolerance} x baseline "
                    f"{base['decode_tok_per_s']})")
    return failures


def check_fleet(fl: dict | None, baseline: dict | None) -> list:
    """Gates over the ``bench_fleet.py`` artifact. The committed
    baseline's ``fleet`` section makes the artifact mandatory — city-scale
    serving must stay gated once it has ever been benchmarked."""
    failures = []
    fleet_floor = float(os.environ.get("FLEET_FLOOR", "0.5"))
    tolerance = float(os.environ.get("BENCH_TOLERANCE", "0.3"))
    base_fl = (baseline or {}).get("fleet")
    if fl is None:
        # FLEET_OPTIONAL=1 is for jobs that legitimately never run the
        # fleet bench (e.g. the forced-multi-device sweep); everywhere
        # else a baselined fleet section makes the artifact mandatory
        if base_fl is not None \
                and os.environ.get("FLEET_OPTIONAL") != "1":
            failures.append("fleet artifact missing but the committed "
                            "baseline has a fleet section — run "
                            "bench_fleet.py and pass its JSON "
                            "(or set FLEET_OPTIONAL=1)")
        return failures

    ab = fl.get("autoscale_ab")
    if ab is None:
        failures.append("autoscale_ab missing from the fleet artifact")
    elif not ab.get("autoscaler_wins"):
        failures.append(
            "autoscaled cluster must beat the equal-aggregate-slot fixed "
            "baseline on session-SLO miss rate: "
            f"autoscaled {ab.get('autoscaled')} vs fixed {ab.get('fixed')}")

    scaling = fl.get("scaling") or []
    if not scaling:
        failures.append("UE scaling curve missing from the fleet artifact")
    else:
        anchor = scaling[0]
        floor = fleet_floor * anchor["decode_tok_per_s"]
        for row in scaling:
            if row["finished"] + row["rejected"] != row["ues"]:
                failures.append(
                    f"fleet ues={row['ues']}: finished {row['finished']} "
                    f"+ rejected {row['rejected']} != {row['ues']} — "
                    "every UE must terminate exactly once")
            if row["ues"] > anchor["ues"] \
                    and row["decode_tok_per_s"] < floor:
                failures.append(
                    f"fleet ues={row['ues']}: decode "
                    f"{row['decode_tok_per_s']} tok/s fell below "
                    f"{floor:.1f} ({fleet_floor} x the {anchor['ues']}-UE "
                    f"{anchor['decode_tok_per_s']} from the same run) — "
                    "offered load must not crater the served rate")
    if base_fl is not None:
        base_rows = {r["ues"]: r for r in base_fl.get("scaling", [])}
        for row in scaling:
            base = base_rows.get(row["ues"])
            if base is None:
                continue
            floor = tolerance * base["decode_tok_per_s"]
            if row["decode_tok_per_s"] < floor:
                failures.append(
                    f"fleet ues={row['ues']}: decode "
                    f"{row['decode_tok_per_s']} tok/s regressed below "
                    f"{floor:.1f} ({tolerance} x baseline "
                    f"{base['decode_tok_per_s']})")
    return failures


def check_kernels(kn: dict | None, baseline: dict | None) -> list:
    """Gates over the ``bench_kernels.py --json`` artifact. The committed
    baseline's ``kernels`` section makes the artifact mandatory — the
    megakernel's fused-vs-unfused win must stay gated once benchmarked."""
    failures = []
    mega_floor = float(os.environ.get("MEGA_FLOOR", "1.0"))
    tolerance = float(os.environ.get("BENCH_TOLERANCE", "0.3"))
    base_kn = (baseline or {}).get("kernels")
    if kn is None:
        if base_kn is not None \
                and os.environ.get("KERNELS_OPTIONAL") != "1":
            failures.append("kernels artifact missing but the committed "
                            "baseline has a kernels section — run "
                            "bench_kernels.py --json and pass its JSON "
                            "(or set KERNELS_OPTIONAL=1)")
        return failures

    if not kn.get("mega_parity_ok"):
        failures.append(
            "fused decode tail diverged from the unfused "
            "boundary+head+argmax chain — the megakernel path must be "
            "token-identical before its timing means anything")
    if not kn.get("boundary_mixed_parity_ok"):
        failures.append("interpret-mode boundary kernel diverged from "
                        "the jnp reference")
    speedup = kn.get("mega_speedup")
    if speedup is None:
        failures.append("mega_speedup missing from the kernels artifact")
    elif speedup < mega_floor:
        failures.append(
            f"fused decode tick speedup {speedup:.2f}x fell below the "
            f"{mega_floor}x floor (fused {kn.get('mega_fused_tick_us')} "
            f"us vs unfused {kn.get('mega_unfused_chain_us')} us — the "
            "megakernel must not lose to the chain it replaced)")
    if base_kn is not None:
        base_us = base_kn.get("mega_fused_tick_us")
        new_us = kn.get("mega_fused_tick_us")
        if base_us and new_us is not None \
                and new_us > base_us / tolerance:
            failures.append(
                f"fused decode tick {new_us:.0f} us regressed above "
                f"{base_us / tolerance:.0f} (baseline {base_us:.0f} / "
                f"tolerance {tolerance})")
    return failures


def check_slot_scaling(sc: dict | None) -> list:
    """Gates over the mesh-sharded slot-scaling sweep. Required only when
    ``REQUIRE_SLOT_SCALING=1`` (the multi-device CI job) — a single-device
    bench run legitimately has no sharded rows to report."""
    failures = []
    shard_floor = float(os.environ.get("SHARD_FLOOR", "0.1"))
    required = os.environ.get("REQUIRE_SLOT_SCALING") == "1"
    if sc is None:
        if required:
            failures.append("slot_scaling missing from the bench artifact "
                            "(REQUIRE_SLOT_SCALING=1)")
        return failures
    rows = sc.get("rows", [])
    base = next((r for r in rows if r["dp"] == 1), None)
    if required and sc.get("skipped_dps"):
        failures.append(
            f"slot_scaling skipped dp={sc['skipped_dps']} — the "
            "multi-device job must run every requested dp level")
    if base is None:
        failures.append("slot_scaling sweep has no dp=1 row to anchor "
                        "the SHARD_FLOOR check")
        return failures
    for r in rows:
        if r["finished"] != r["requests"]:
            failures.append(
                f"slot_scaling dp={r['dp']}: finished {r['finished']} of "
                f"{r['requests']} requests — sharded engines must drain "
                "the full workload")
        if r["dp"] > 1:
            floor = shard_floor * base["decode_tok_per_s"]
            if r["decode_tok_per_s"] < floor:
                failures.append(
                    f"slot_scaling dp={r['dp']}: decode "
                    f"{r['decode_tok_per_s']} tok/s fell below "
                    f"{floor:.1f} ({shard_floor} x the dp=1 "
                    f"{base['decode_tok_per_s']} from the same run)")
    return failures


def check_telemetry(new: dict) -> list:
    """Gates over the serving artifact's telemetry sections: mandatory
    latency percentiles at every offered-load level, and the
    instrumented-vs-plain overhead A/B (observability must not tax the
    hot path)."""
    failures = []
    tel_floor = float(os.environ.get("TELEMETRY_FLOOR", "0.95"))
    for lvl in new.get("levels", []):
        lat = lvl.get("latency") or {}
        for name in ("engine.ttft_s", "engine.intertoken_s"):
            h = lat.get(name) or {}
            if any(k not in h for k in ("p50", "p90", "p99")):
                failures.append(
                    f"load {lvl.get('offered_load_req_per_tick')}: latency "
                    f"section missing {name} p50/p90/p99 — every level "
                    "must report instrumented percentiles")
    ov = new.get("telemetry_overhead")
    if ov is None:
        failures.append("telemetry_overhead missing from the bench "
                        "artifact — the instrumented-vs-plain A/B must "
                        "run (do not pass --overhead-repeats 0 in CI)")
    elif (ov.get("ratio") or 0.0) < tel_floor:
        failures.append(
            f"instrumented engine kept only {ov.get('ratio')}x the plain "
            f"engine's decode rate, below the {tel_floor} floor "
            f"(telemetry {ov.get('telemetry_tok_per_s')} vs plain "
            f"{ov.get('plain_tok_per_s')} tok/s)")
    return failures


def check(new: dict, baseline: dict | None) -> list:
    failures = []
    min_speedup = float(os.environ.get("MIN_LOOP_SPEEDUP", "1.15"))
    tolerance = float(os.environ.get("BENCH_TOLERANCE", "0.3"))

    tr = new.get("channel_trace")
    if tr is None:
        # a silently-missing trace must not un-gate the paper's headline
        # adaptive-vs-frozen claim — CI always passes --channel-trace
        failures.append("channel_trace missing from the bench artifact")
    elif not tr.get("adaptive_wins"):
        failures.append(
            "adaptive controller must use <= wire bytes/token at an "
            "equal-or-better deadline-miss rate than admission-frozen "
            f"modes: {tr.get('adaptive')} vs {tr.get('frozen')}")

    ec = new.get("engine_comparison")
    if ec is None:
        failures.append("engine_comparison missing from the bench artifact")
    elif ec["decode_speedup"] < min_speedup:
        failures.append(
            f"device-resident decode loop speedup {ec['decode_speedup']}x "
            f"fell below the {min_speedup}x floor "
            f"(device {ec['device_loop']['decode_tok_per_s']} vs host "
            f"{ec['host_loop']['decode_tok_per_s']} tok/s)")

    lp = new.get("long_prompt")
    if lp is None:
        # CI benches the paged default arch — a silently-missing section
        # must not un-gate the page-budget admission claim
        failures.append("long_prompt missing from the bench artifact")
    else:
        if lp["over_capacity"] != 0 or lp["truncated"] != 0:
            failures.append(
                "paged long-prompt scenario must admit every prompt whole: "
                f"{lp['over_capacity']} over capacity, "
                f"{lp['truncated']} truncated")
        if lp["finished"] != lp["requests"]:
            failures.append(
                f"paged long-prompt scenario finished {lp['finished']} of "
                f"{lp['requests']} requests — parked sessions must drain, "
                "not starve")
        if lp["dense_over_capacity"] != lp["requests"]:
            failures.append(
                "the dense control engine should reject every "
                f"longer-than-cache prompt, rejected "
                f"{lp['dense_over_capacity']} of {lp['requests']} — the "
                "scenario is not actually exceeding the dense cache")

    failures += check_slot_scaling(new.get("slot_scaling"))
    failures += check_telemetry(new)

    if baseline is not None:
        base_levels = {l["offered_load_req_per_tick"]: l
                       for l in baseline.get("levels", [])}
        for lvl in new.get("levels", []):
            base = base_levels.get(lvl["offered_load_req_per_tick"])
            if base is None:
                continue
            floor = tolerance * base["decode_tok_per_s"]
            if lvl["decode_tok_per_s"] < floor:
                failures.append(
                    f"load {lvl['offered_load_req_per_tick']}: decode "
                    f"{lvl['decode_tok_per_s']} tok/s regressed below "
                    f"{floor:.1f} ({tolerance} x baseline "
                    f"{base['decode_tok_per_s']})")
        blp = baseline.get("long_prompt")
        if lp is not None and blp is not None:
            floor = tolerance * blp["decode_tok_per_s"]
            if lp["decode_tok_per_s"] < floor:
                failures.append(
                    f"paged long-prompt decode {lp['decode_tok_per_s']} "
                    f"tok/s regressed below {floor:.1f} ({tolerance} x "
                    f"baseline {blp['decode_tok_per_s']})")
        bec = baseline.get("engine_comparison")
        if ec is not None and bec is not None:
            floor = tolerance * bec["device_loop"]["decode_tok_per_s"]
            if ec["device_loop"]["decode_tok_per_s"] < floor:
                failures.append(
                    f"device-loop decode {ec['device_loop']['decode_tok_per_s']} "
                    f"tok/s regressed below {floor:.1f} ({tolerance} x "
                    f"baseline {bec['device_loop']['decode_tok_per_s']})")
    return failures


def main(argv) -> int:
    new = json.load(open(argv[1]))
    baseline = json.load(open(argv[2])) if len(argv) > 2 else None
    cluster = json.load(open(argv[3])) if len(argv) > 3 else None
    fleet = json.load(open(argv[4])) if len(argv) > 4 else None
    kernels_art = json.load(open(argv[5])) if len(argv) > 5 else None
    kernels = (kernels_art or {}).get("kernels")
    failures = check(new, baseline)
    failures += check_fleet(fleet, baseline)
    failures += check_kernels(kernels, baseline)
    summary = {
        "engine_comparison": new.get("engine_comparison"),
        "levels": [{k: l[k] for k in ("offered_load_req_per_tick",
                                      "decode_tok_per_s")}
                   for l in new.get("levels", [])],
        "adaptive_wins": (new.get("channel_trace") or {}).get(
            "adaptive_wins"),
        "long_prompt": {k: (new.get("long_prompt") or {}).get(k)
                        for k in ("finished", "requests", "over_capacity",
                                  "decode_tok_per_s", "page_occupancy")},
        "telemetry_overhead_ratio": (new.get("telemetry_overhead")
                                     or {}).get("ratio"),
    }
    if new.get("slot_scaling") is not None:
        summary["slot_scaling"] = [
            {k: r[k] for k in ("dp", "n_slots", "decode_tok_per_s")}
            for r in new["slot_scaling"].get("rows", [])]
    if cluster is not None:
        failures += check_cluster(cluster, baseline)
        summary["migration_wins"] = (cluster.get("handover_ab") or {}).get(
            "migration_wins")
        summary["scaling"] = [{k: s[k] for k in ("replicas",
                                                 "decode_tok_per_s")}
                              for s in cluster.get("scaling", [])]
    if fleet is not None:
        summary["autoscaler_wins"] = (fleet.get("autoscale_ab") or {}).get(
            "autoscaler_wins")
        summary["fleet_scaling"] = [
            {k: r[k] for k in ("ues", "decode_tok_per_s",
                               "session_slo_miss_rate")}
            for r in fleet.get("scaling", [])]
    if kernels is not None:
        summary["kernels"] = {k: kernels.get(k)
                              for k in ("mega_fused_tick_us",
                                        "mega_unfused_chain_us",
                                        "mega_speedup", "mega_parity_ok")}
    print(json.dumps(summary, indent=1))
    for f in failures:
        print(f"BENCH CHECK FAILED: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
