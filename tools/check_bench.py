"""CI gate over the serving benchmark artifact (stdlib only).

    python tools/check_bench.py NEW.json [BASELINE.json]

Asserts, against the fresh ``bench_serving.py --json`` output:

1. ``channel_trace.adaptive_wins`` — the in-flight adaptive controller must
   use <= decode wire bytes/token at an equal-or-better deadline-miss rate
   than admission-frozen modes (the paper's dynamic-adaptation claim);
2. ``engine_comparison.decode_speedup`` — the device-resident decode loop
   must beat the legacy host loop by at least ``MIN_LOOP_SPEEDUP`` (a
   machine-independent in-run ratio: both loops run on the same box in the
   same process);
3. decode tokens/s must not regress below ``BENCH_TOLERANCE`` x the
   committed baseline (matched per offered-load level, plus the
   device-loop figure). The tolerance is deliberately loose — CI runners
   vary widely in absolute speed; the in-run ratio above is the sharp
   check — and the committed baseline should be refreshed by any PR that
   intentionally moves serving performance.

Environment overrides: ``MIN_LOOP_SPEEDUP`` (default 1.15),
``BENCH_TOLERANCE`` (default 0.3).
"""
from __future__ import annotations

import json
import os
import sys


def check(new: dict, baseline: dict | None) -> list:
    failures = []
    min_speedup = float(os.environ.get("MIN_LOOP_SPEEDUP", "1.15"))
    tolerance = float(os.environ.get("BENCH_TOLERANCE", "0.3"))

    tr = new.get("channel_trace")
    if tr is None:
        # a silently-missing trace must not un-gate the paper's headline
        # adaptive-vs-frozen claim — CI always passes --channel-trace
        failures.append("channel_trace missing from the bench artifact")
    elif not tr.get("adaptive_wins"):
        failures.append(
            "adaptive controller must use <= wire bytes/token at an "
            "equal-or-better deadline-miss rate than admission-frozen "
            f"modes: {tr.get('adaptive')} vs {tr.get('frozen')}")

    ec = new.get("engine_comparison")
    if ec is None:
        failures.append("engine_comparison missing from the bench artifact")
    elif ec["decode_speedup"] < min_speedup:
        failures.append(
            f"device-resident decode loop speedup {ec['decode_speedup']}x "
            f"fell below the {min_speedup}x floor "
            f"(device {ec['device_loop']['decode_tok_per_s']} vs host "
            f"{ec['host_loop']['decode_tok_per_s']} tok/s)")

    if baseline is not None:
        base_levels = {l["offered_load_req_per_tick"]: l
                       for l in baseline.get("levels", [])}
        for lvl in new.get("levels", []):
            base = base_levels.get(lvl["offered_load_req_per_tick"])
            if base is None:
                continue
            floor = tolerance * base["decode_tok_per_s"]
            if lvl["decode_tok_per_s"] < floor:
                failures.append(
                    f"load {lvl['offered_load_req_per_tick']}: decode "
                    f"{lvl['decode_tok_per_s']} tok/s regressed below "
                    f"{floor:.1f} ({tolerance} x baseline "
                    f"{base['decode_tok_per_s']})")
        bec = baseline.get("engine_comparison")
        if ec is not None and bec is not None:
            floor = tolerance * bec["device_loop"]["decode_tok_per_s"]
            if ec["device_loop"]["decode_tok_per_s"] < floor:
                failures.append(
                    f"device-loop decode {ec['device_loop']['decode_tok_per_s']} "
                    f"tok/s regressed below {floor:.1f} ({tolerance} x "
                    f"baseline {bec['device_loop']['decode_tok_per_s']})")
    return failures


def main(argv) -> int:
    new = json.load(open(argv[1]))
    baseline = json.load(open(argv[2])) if len(argv) > 2 else None
    failures = check(new, baseline)
    summary = {
        "engine_comparison": new.get("engine_comparison"),
        "levels": [{k: l[k] for k in ("offered_load_req_per_tick",
                                      "decode_tok_per_s")}
                   for l in new.get("levels", [])],
        "adaptive_wins": (new.get("channel_trace") or {}).get(
            "adaptive_wins"),
    }
    print(json.dumps(summary, indent=1))
    for f in failures:
        print(f"BENCH CHECK FAILED: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
