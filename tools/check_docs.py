#!/usr/bin/env python3
"""Markdown link checker: relative file links and internal anchors.

Docs rot silently — a renamed module or a reworded heading breaks
``docs/*.md`` cross-references without failing anything. This checker walks
every ``*.md`` in the repo (skipping dot-directories) and verifies, with the
standard library only:

* relative file links ``[text](path)`` resolve to an existing file or
  directory (relative to the markdown file's own directory);
* anchor links ``[text](#heading)`` and ``[text](path#heading)`` point at a
  heading that GitHub's slugifier would produce in the target file;
* external links (``http(s)://``, ``mailto:``) are NOT fetched — offline CI
  must stay deterministic.

Run from anywhere: ``python tools/check_docs.py [repo_root]``. Exit code 1
when any link is broken; the CI ``docs`` job runs this on every PR.
"""
from __future__ import annotations

import functools
import re
import sys
from pathlib import Path
from typing import Dict, List, Set

# [text](target) — but not ![image](...) captures too; images use the same
# resolution rules so they are checked identically. Nested brackets in the
# text are out of scope.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
_CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code ticks, lowercase,
    drop punctuation except hyphens/underscores, spaces become hyphens.
    Underscores stay — GitHub keeps them (``payload_bytes`` →
    ``#payload_bytes``); stripping them as emphasis would mis-slug every
    code-identifier heading."""
    text = re.sub(r"[`*]", "", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # linked headings
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def strip_fenced_code(text: str) -> str:
    """Blank out every line inside ``` / ~~~ fenced blocks (fences may be
    indented, e.g. inside list items — detection matches on the stripped
    line, the same rule ``heading_slugs`` uses), so link-looking strings
    in code examples are never link-checked."""
    out: List[str] = []
    in_fence = False
    for line in text.splitlines():
        if _CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            out.append("")
            continue
        out.append("" if in_fence else line)
    return "\n".join(out)


@functools.lru_cache(maxsize=None)
def heading_slugs(md_path: Path) -> Set[str]:
    """All anchor slugs a markdown file exposes (dedup suffixes -1, -2 …
    the way GitHub numbers repeated headings). Fenced code blocks are
    skipped so ``# comment`` lines inside ``` fences aren't headings."""
    counts: Dict[str, int] = {}
    slugs: Set[str] = set()
    for line in strip_fenced_code(
            md_path.read_text(encoding="utf-8")).splitlines():
        m = _HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def iter_markdown(root: Path):
    for p in sorted(root.rglob("*.md")):
        if any(part.startswith(".") for part in p.relative_to(root).parts[:-1]):
            continue            # .git, .github READMEs stay out of scope
        yield p


def check_file(md: Path, root: Path) -> List[str]:
    errors: List[str] = []
    text = strip_fenced_code(md.read_text(encoding="utf-8"))
    text = re.sub(r"`[^`\n]*`", "", text)       # inline code is not links
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            dest = (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md.relative_to(root)}: broken link "
                              f"'{target}' -> {path_part} does not exist")
                continue
        else:
            dest = md
        if anchor:
            if dest.suffix != ".md" or dest.is_dir():
                continue        # anchors into non-markdown: out of scope
            if anchor not in heading_slugs(dest):
                errors.append(f"{md.relative_to(root)}: broken anchor "
                              f"'{target}' — no heading slug '#{anchor}' in "
                              f"{dest.relative_to(root)}")
    return errors


def check_repo(root: Path) -> List[str]:
    errors: List[str] = []
    for md in iter_markdown(root):
        errors.extend(check_file(md, root))
    return errors


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    root = Path(argv[0]).resolve() if argv else Path(__file__).resolve().parents[1]
    errors = check_repo(root)
    n_files = len(list(iter_markdown(root)))
    if errors:
        print(f"check_docs: {len(errors)} broken link(s) across "
              f"{n_files} markdown files:")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"check_docs: OK — {n_files} markdown files, all relative links "
          f"and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
